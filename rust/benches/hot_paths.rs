//! Bench: micro/hot-path measurements feeding EXPERIMENTS.md §Perf —
//! per-gradient native cost across dimensions, fused vr_step vs a naive
//! 3-pass update, whole native epochs, lazy vs eager vs dense sparse
//! epochs (writes `results/BENCH_sparse_steps.json`), mini-batched
//! round throughput at B = 1/8/32/64 on both storage layouts through
//! the real `RoundMachine` driver (writes
//! `results/BENCH_batched_steps.json`; its "exact" block pins the
//! measured gradient/update budget split), HLO-engine epochs
//! (dispatch overhead of the AOT path), simulator event throughput,
//! server apply latency, parallel-simulator wall-clock scaling (writes
//! `results/BENCH_parallel_sim.json`), exact quantized-payload frame
//! sizes per wire format (writes `results/BENCH_wire_bytes.json`), and
//! the hostile-network scenario sweep (writes
//! `results/BENCH_scenario_sweep.json`).
//!
//! Sections can be selected by substring:
//! `cargo bench --bench hot_paths -- parallel_sim` runs only the
//! parallel-simulator scaling section (the one CI exercises).

mod common;

use centralvr::config::schema::Algorithm;
use centralvr::data::shard::ShardedDataset;
use centralvr::data::synth;
use centralvr::dist::messages::Upload;
use centralvr::dist::server::ServerState;
use centralvr::dist::DistConfig;
use centralvr::exec::engine::{EpochEngine, NativeEngine};
use centralvr::exec::simulator::{self, SimParams};
use centralvr::hlo_exec::HloEngine;
use centralvr::model::glm::Problem;
use centralvr::util::math;
use centralvr::util::rng::Pcg64;
use centralvr::util::timer::black_box;

fn naive_vr_step(x: &mut [f32], a: &[f32], gbar: &[f32], coef: f32, eta: f32, lam: f32) {
    // 3-pass textbook version (allocates) — the §Perf baseline
    let mut g: Vec<f32> = a.iter().map(|v| coef * v).collect();
    for (gj, bj) in g.iter_mut().zip(gbar) {
        *gj += bj;
    }
    for (gj, xj) in g.iter_mut().zip(x.iter()) {
        *gj += 2.0 * lam * xj;
    }
    for (xj, gj) in x.iter_mut().zip(&g) {
        *xj -= eta * gj;
    }
}

fn main() {
    // substring section filter: no filter args = run everything. Cargo
    // appends flags like --bench to harness-less binaries, so anything
    // starting with '-' is not a section filter.
    let only: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let enabled =
        |name: &str| only.is_empty() || only.iter().any(|a| name.contains(a.as_str()));
    let b = common::Bench::group("hot_paths");

    // --- per-gradient native cost across d ---
    if enabled("native_epoch") {
        for d in [20usize, 100, 1000] {
            let n = 2000;
            let ds = synth::toy_classification(n, d, 1);
            let mut eng = NativeEngine::new();
            let mut x = vec![0.0f32; d];
            let mut alpha = vec![0.0f32; n];
            let gbar = vec![0.0f32; d];
            let mut gtilde = vec![0.0f32; d];
            let perm: Vec<u32> = (0..n as u32).collect();
            let s = b.case(&format!("native_epoch_d{d}"), 2, 10, || {
                eng.centralvr_epoch(
                    Problem::Logistic,
                    &ds,
                    &perm,
                    &mut x,
                    &mut alpha,
                    &gbar,
                    &mut gtilde,
                    1e-3,
                    1e-4,
                );
                black_box(x[0])
            });
            b.metric(
                &format!("native_ns_per_grad_d{d}"),
                s.median * 1e9 / n as f64,
                "ns/grad",
            );
            b.metric(
                &format!("native_gflops_d{d}"),
                (n * (8 * d + 20)) as f64 / s.median / 1e9,
                "GFLOP/s effective",
            );
        }
    }

    // --- fused vr_step vs naive 3-pass ---
    if enabled("vr_step") {
        let d = 100;
        let mut r = Pcg64::new(2);
        let a: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
        let gbar: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
        let mut x = vec![0.1f32; d];
        let fused = b.case("vr_step_fused_d100_x10k", 3, 20, || {
            for _ in 0..10_000 {
                math::vr_step(&mut x, &a, &gbar, 0.3, 1e-3, 1e-4);
            }
            black_box(x[0])
        });
        let mut x = vec![0.1f32; d];
        let naive = b.case("vr_step_naive_d100_x10k", 3, 20, || {
            for _ in 0..10_000 {
                naive_vr_step(&mut x, &a, &gbar, 0.3, 1e-3, 1e-4);
            }
            black_box(x[0])
        });
        b.metric("vr_step_fused_speedup", naive.median / fused.median, "x");
    }

    // --- CSR vs dense CentralVR epoch at rcv1-like 1% density ---
    // The ISSUE-3 acceptance workload: n=50k, d=5k, 1% density. The dense
    // twin materializes a 50k x 5k f32 matrix (~1 GB); both epochs run the
    // identical update sequence, so the endpoint iterates double as the
    // CSR-vs-dense parity check at full scale.
    if enabled("csr") {
        let (n, d) = (50_000usize, 5_000usize);
        let sp = synth::sparse_classification(n, d, 0.01, 7);
        let dn = sp.to_dense();
        let mut eng = NativeEngine::new();
        let perm: Vec<u32> = (0..n as u32).collect();
        let gbar = vec![0.0f32; d];

        let mut x_sp = vec![0.0f32; d];
        let mut alpha_sp = vec![0.0f32; n];
        let mut gtilde = vec![0.0f32; d];
        let s_sp = b.case("centralvr_epoch_csr_n50k_d5k_1pct", 1, 3, || {
            x_sp.fill(0.0);
            alpha_sp.fill(0.0);
            eng.centralvr_epoch(
                Problem::Logistic,
                &sp,
                &perm,
                &mut x_sp,
                &mut alpha_sp,
                &gbar,
                &mut gtilde,
                1e-3,
                1e-4,
            );
            black_box(x_sp[0])
        });
        let mut x_dn = vec![0.0f32; d];
        let mut alpha_dn = vec![0.0f32; n];
        let s_dn = b.case("centralvr_epoch_dense_n50k_d5k_1pct", 1, 3, || {
            x_dn.fill(0.0);
            alpha_dn.fill(0.0);
            eng.centralvr_epoch(
                Problem::Logistic,
                &dn,
                &perm,
                &mut x_dn,
                &mut alpha_dn,
                &gbar,
                &mut gtilde,
                1e-3,
                1e-4,
            );
            black_box(x_dn[0])
        });
        b.metric("csr_vs_dense_epoch_speedup", s_dn.median / s_sp.median, "x");
        b.metric(
            "csr_ns_per_grad_d5k_1pct",
            s_sp.median * 1e9 / n as f64,
            "ns/grad",
        );
        // parity of the final-run iterates (both start from x = 0, same
        // perm). The CSR epoch now runs lazy decay (f64 closed-form
        // catch-up) while the dense epoch chains 50k f32 fmas per
        // coordinate; the rounding gap random-walks with sqrt(steps), so
        // at this scale the bound is 1e-4, not the 1e-5 of the small
        // sparse_parity suite.
        let diff = math::max_abs_diff(&x_sp, &x_dn) as f64;
        b.metric("csr_vs_dense_epoch_max_abs_diff", diff, "max|dx|");
        assert!(diff < 1e-4, "CSR epoch drifted from densified run: {diff}");
    }

    // --- lazy vs eager vs dense sparse CentralVR epochs (PR-7 tentpole) ---
    // The lazy path (engine: per-coordinate just-in-time decay via
    // util::lazy) against the eager reference (the pre-lazy engine loop:
    // dense scale/gbar pass per sample via vr_step_row) and the dense
    // twin, all at the acceptance workload n=50k d=5k 1%. gbar is nonzero
    // so lazy catch-up pays its full closed form. Writes the baseline
    // artifact results/BENCH_sparse_steps.json.
    if enabled("sparse_steps") {
        let (n, d) = (50_000usize, 5_000usize);
        let sp = synth::sparse_classification(n, d, 0.01, 11);
        let perm: Vec<u32> = (0..n as u32).collect();
        let (eta, lam) = (1e-3f32, 1e-4f32);
        let mut r = Pcg64::new(3);
        let gbar: Vec<f32> = (0..d).map(|_| 0.01 * r.normal() as f32).collect();
        let mut eng = NativeEngine::new();
        let mut alpha = vec![0.0f32; n];
        let mut gtilde = vec![0.0f32; d];

        let mut x_lz = vec![0.0f32; d];
        let s_lazy = b.case("sparse_steps_lazy_csr", 1, 5, || {
            x_lz.fill(0.0);
            alpha.fill(0.0);
            eng.centralvr_epoch(
                Problem::Logistic,
                &sp,
                &perm,
                &mut x_lz,
                &mut alpha,
                &gbar,
                &mut gtilde,
                eta,
                lam,
            );
            black_box(x_lz[0])
        });

        let mut x_eg = vec![0.0f32; d];
        let s_eager = b.case("sparse_steps_eager_csr", 1, 3, || {
            x_eg.fill(0.0);
            alpha.fill(0.0);
            gtilde.fill(0.0);
            let inv_n = 1.0 / n as f32;
            for &iu in &perm {
                let i = iu as usize;
                let a = sp.row_view(i);
                let c = Problem::Logistic.dloss(math::dot_row(a, &x_eg), sp.label(i));
                math::vr_step_row(&mut x_eg, a, &gbar, c - alpha[i], eta, lam);
                alpha[i] = c;
                math::axpy_row(c * inv_n, a, &mut gtilde);
            }
            black_box(x_eg[0])
        });

        let dn = sp.to_dense(); // ~1 GB twin, dropped at section end
        let mut x_dn = vec![0.0f32; d];
        let s_dense = b.case("sparse_steps_dense", 1, 3, || {
            x_dn.fill(0.0);
            alpha.fill(0.0);
            eng.centralvr_epoch(
                Problem::Logistic,
                &dn,
                &perm,
                &mut x_dn,
                &mut alpha,
                &gbar,
                &mut gtilde,
                eta,
                lam,
            );
            black_box(x_dn[0])
        });
        drop(dn);

        let lazy_vs_eager = s_eager.median / s_lazy.median;
        let lazy_vs_dense = s_dense.median / s_lazy.median;
        b.metric("speedup_lazy_vs_eager", lazy_vs_eager, "x");
        b.metric("speedup_lazy_vs_dense", lazy_vs_dense, "x");
        b.metric(
            "sparse_steps_lazy_ns_per_grad",
            s_lazy.median * 1e9 / n as f64,
            "ns/grad",
        );
        // lazy vs eager endpoint parity — same 1e-4 rationale as `csr`
        // (f64 closed-form catch-up vs a 50k-deep f32 fma chain)
        let diff = math::max_abs_diff(&x_lz, &x_eg) as f64;
        b.metric("sparse_steps_lazy_vs_eager_max_abs_diff", diff, "max|dx|");
        assert!(diff < 1e-4, "lazy epoch drifted from eager reference: {diff}");

        let json = format!(
            "{{\n  \"bench\": \"sparse_steps\",\n  \"workload\": \
             \"centralvr n={n} d={d} density=0.01 eta=1e-3 lam=1e-4\",\n  \
             \"seeded\": true,\n  \
             \"runs\": [\n    \
             {{\"case\": \"lazy_csr\", \"t_epoch_s\": {:.6}}},\n    \
             {{\"case\": \"eager_csr\", \"t_epoch_s\": {:.6}}},\n    \
             {{\"case\": \"dense\", \"t_epoch_s\": {:.6}}}\n  ],\n  \
             \"metrics\": {{\n    \
             \"speedup_lazy_vs_eager\": {lazy_vs_eager:.3},\n    \
             \"speedup_lazy_vs_dense\": {lazy_vs_dense:.3},\n    \
             \"lazy_vs_eager_max_abs_diff\": {diff:.3e}\n  }}\n}}\n",
            s_lazy.median, s_eager.median, s_dense.median
        );
        let out_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../results");
        let path = format!("{out_dir}/BENCH_sparse_steps.json");
        if let Err(e) = std::fs::create_dir_all(out_dir)
            .and_then(|()| std::fs::write(&path, &json))
        {
            println!("hot_paths/sparse_steps: could not write {path}: {e}");
        } else {
            println!("hot_paths/sparse_steps: wrote {path}");
        }
        print!("{json}");
    }

    // --- mini-batched round throughput (ISSUE 10 tentpole) ---
    // B = 1/8/32/64 on both storage layouts at the acceptance workload
    // (n=50k, d=5k, 1% density), driven through the REAL round path: a
    // fresh p=1 CVR-Sync `RoundMachine` per invocation runs its init
    // epoch plus one VR epoch against a `ServerState`, and the closure
    // charges the shared `Counters` from each `RoundOutput` — so the
    // gradient/update budget split in the artifact's "exact" block is
    // measured through `updates_for`, not transcribed, and
    // tools/bench_diff.py hard-fails CI if it ever drifts from the
    // committed baseline. Uses the full `run_case` harness:
    // reproducibility pre-check, explicit warmup/measure phases,
    // min-of-k headline.
    if enabled("batched_steps") {
        use std::sync::Arc;

        use centralvr::dist::local::{LocalNode, RoundMachine};
        use centralvr::metrics::counters::Counters;
        use common::{CounterDelta, CounterField, Phases};

        let (n, d) = (50_000usize, 5_000usize);
        let sp = synth::sparse_classification(n, d, 0.01, 17);
        let dn = sp.to_dense(); // ~1 GB twin, dropped at section end
        // (case, min_s, grad_evals, updates) per configuration
        let mut results: Vec<(String, f64, u64, u64)> = Vec::new();
        for (layout, ds) in [("csr", &sp), ("dense", &dn)] {
            for batch in [1usize, 8, 32, 64] {
                let cfg = DistConfig {
                    algorithm: Algorithm::CentralVrSync,
                    p: 1,
                    eta: 1e-3,
                    max_rounds: 2, // init epoch + one VR epoch = 2n grads
                    tol: 0.0,
                    batch,
                    ..Default::default()
                };
                let counters = Counters::new();
                let mut evals =
                    CounterDelta::new(CounterField::GradEvals, Arc::clone(&counters));
                let mut iters =
                    CounterDelta::new(CounterField::Iterations, Arc::clone(&counters));
                let case = format!("{layout}_b{batch}");
                let run = b.run_case(
                    &case,
                    Phases::new(1, 3),
                    &mut [&mut evals, &mut iters],
                    || {
                        let node = LocalNode::new(0, ds, Problem::Logistic, cfg, n);
                        let mut m = RoundMachine::new(node);
                        let mut server = ServerState::new(d, 1, cfg.easgd_beta);
                        while let Some(out) = m.compute() {
                            counters.add_grad_evals(out.evals);
                            counters.add_iterations(out.iters);
                            server.apply_barrier_round(&[out.upload], &[1.0]).unwrap();
                            m.absorb(server.view());
                        }
                        m.node().x()[0].to_bits() as u64
                    },
                );
                let grads = run.observations[0].1 as u64;
                let updates = run.observations[1].1 as u64;
                b.metric(
                    &format!("batched_ns_per_grad_{case}"),
                    run.min_s * 1e9 / grads as f64,
                    "ns/grad",
                );
                results.push((case, run.min_s, grads, updates));
            }
        }
        drop(dn);

        let time_of = |k: &str| results.iter().find(|r| r.0 == k).unwrap().1;
        let speedup_csr = time_of("csr_b1") / time_of("csr_b32");
        let speedup_dense = time_of("dense_b1") / time_of("dense_b32");
        b.metric("batched_speedup_csr_b32", speedup_csr, "x");
        b.metric("batched_speedup_dense_b32", speedup_dense, "x");

        let exact: Vec<String> = results
            .iter()
            .flat_map(|(case, _, grads, updates)| {
                [
                    format!("    \"{case}_grad_evals\": {grads}"),
                    format!("    \"{case}_updates\": {updates}"),
                ]
            })
            .collect();
        let runs: Vec<String> = results
            .iter()
            .map(|(case, min_s, grads, _)| {
                format!(
                    "    {{\"case\": \"{case}\", \"t_rounds_s\": {min_s:.6}, \
                     \"ns_per_grad\": {:.1}}}",
                    min_s * 1e9 / *grads as f64
                )
            })
            .collect();
        let json = format!(
            "{{\n  \"bench\": \"batched_steps\",\n  \"workload\": \
             \"cvr-sync p=1 init+vr rounds, n={n} d={d} density=0.01 eta=1e-3\",\n  \
             \"seeded\": true,\n  \"exact\": {{\n{}\n  }},\n  \"runs\": [\n{}\n  ],\n  \
             \"metrics\": {{\n    \
             \"batched_speedup_csr_b32\": {speedup_csr:.3},\n    \
             \"batched_speedup_dense_b32\": {speedup_dense:.3}\n  }}\n}}\n",
            exact.join(",\n"),
            runs.join(",\n")
        );
        let out_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../results");
        let path = format!("{out_dir}/BENCH_batched_steps.json");
        if let Err(e) = std::fs::create_dir_all(out_dir)
            .and_then(|()| std::fs::write(&path, &json))
        {
            println!("hot_paths/batched_steps: could not write {path}: {e}");
        } else {
            println!("hot_paths/batched_steps: wrote {path}");
        }
        print!("{json}");
    }

    // --- HLO engine epoch (AOT path dispatch cost) ---
    if enabled("hlo_epoch") {
        let dir = HloEngine::default_dir();
        if HloEngine::AVAILABLE && std::path::Path::new(&dir).join("manifest.json").exists() {
            let (n, d) = (256usize, 16usize);
            let ds = synth::toy_classification(n, d, 3);
            let mut hlo = HloEngine::new(&dir).expect("hlo");
            let mut nat = NativeEngine::new();
            let mut x = vec![0.0f32; d];
            let mut alpha = vec![0.0f32; n];
            let gbar = vec![0.0f32; d];
            let mut gtilde = vec![0.0f32; d];
            let perm: Vec<u32> = (0..n as u32).collect();
            let h = b.case("hlo_epoch_n256_d16", 2, 10, || {
                hlo.centralvr_epoch(
                    Problem::Logistic,
                    &ds,
                    &perm,
                    &mut x,
                    &mut alpha,
                    &gbar,
                    &mut gtilde,
                    1e-3,
                    1e-4,
                );
                black_box(x[0])
            });
            let mut x = vec![0.0f32; d];
            let nn = b.case("native_epoch_n256_d16", 2, 10, || {
                nat.centralvr_epoch(
                    Problem::Logistic,
                    &ds,
                    &perm,
                    &mut x,
                    &mut alpha,
                    &gbar,
                    &mut gtilde,
                    1e-3,
                    1e-4,
                );
                black_box(x[0])
            });
            b.metric("hlo_vs_native_epoch", h.median / nn.median, "x (HLO/native)");
        } else {
            println!("hot_paths/hlo_epoch: SKIPPED (needs --features pjrt and `make artifacts`)");
        }
    }

    // --- server apply latency ---
    if enabled("server_apply") {
        let d = 1000;
        let mut server = ServerState::new(d, 16, 0.9);
        let up = Upload::Delta {
            dx: vec![0.01; d],
            dgbar: vec![0.01; d],
        };
        let s = b.case("server_apply_delta_d1000", 10, 50, || {
            for _ in 0..1000 {
                server.apply_delta(&up);
            }
            black_box(server.x[0])
        });
        b.metric("server_apply_ns", s.median * 1e9 / 1000.0, "ns/apply");
    }

    // --- simulator event throughput ---
    if enabled("simulator_events") {
        let (p, n_per, d) = (16usize, 100usize, 20usize);
        let data =
            ShardedDataset::from_shards(synth::toy_least_squares_per_worker(p, n_per, d, 5));
        let cfg = DistConfig {
            algorithm: Algorithm::CentralVrAsync,
            p,
            eta: 0.125 / d as f32,
            max_rounds: 40,
            tol: 0.0,
            record_every: 1_000_000, // metrics off: measure the engine
            ..Default::default()
        };
        let mut events = 0u64;
        let s = b.case("simulator_40rounds_p16", 1, 5, || {
            let rep = simulator::run(Problem::Ridge, &data, cfg, SimParams::analytic(d));
            events = rep.events;
            black_box(rep.trace.grad_evals)
        });
        b.metric(
            "simulator_events_per_s",
            events as f64 / s.median,
            "events/s",
        );
        b.metric(
            "simulator_grads_per_s",
            (40 * p * n_per) as f64 / s.median,
            "grad evals/s",
        );
    }

    // --- parallel simulator wall-clock scaling ---
    // The compute/apply split lets the simulator fan worker compute
    // halves across threads with bit-identical results; this section
    // measures the wall-clock payoff at p = 1/4/8/16 (threads = 1 vs
    // available cores) on a compute-heavy CVR-Sync workload and writes
    // the perf-trajectory artifact results/BENCH_parallel_sim.json.
    if enabled("parallel_sim") {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let (n_per, d, rounds) = (3000usize, 100usize, 6usize);
        let mut entries = Vec::new();
        for p in [1usize, 4, 8, 16] {
            let data = ShardedDataset::from_shards(synth::toy_least_squares_per_worker(
                p, n_per, d, 5,
            ));
            let cfg = DistConfig {
                algorithm: Algorithm::CentralVrSync,
                p,
                eta: 0.125 / d as f32,
                max_rounds: rounds,
                tol: 0.0,
                record_every: 1_000_000, // metrics off: measure the engine
                ..Default::default()
            };
            let serial = b.case(&format!("parallel_sim_p{p}_t1"), 1, 3, || {
                let rep =
                    simulator::run(Problem::Ridge, &data, cfg, SimParams::analytic(d));
                black_box(rep.trace.grad_evals)
            });
            let threads = cores.max(2); // >1 even on a 1-core host: measures overhead honestly
            let parallel = b.case(&format!("parallel_sim_p{p}_t{threads}"), 1, 3, || {
                let rep = simulator::run(
                    Problem::Ridge,
                    &data,
                    cfg,
                    SimParams::analytic(d).with_threads(threads),
                );
                black_box(rep.trace.grad_evals)
            });
            let speedup = serial.median / parallel.median;
            b.metric(&format!("parallel_sim_speedup_p{p}"), speedup, "x");
            entries.push(format!(
                "    {{\"p\": {p}, \"threads\": {threads}, \"t_serial_s\": {:.6}, \
                 \"t_parallel_s\": {:.6}, \"speedup\": {:.3}}}",
                serial.median, parallel.median, speedup
            ));
        }
        let note = if cores < 4 {
            format!(
                "host has only {cores} core(s): fan-out cannot exceed that; \
                 speedups are capped accordingly"
            )
        } else {
            String::from("speedup at p=16 is the Fig-3-scale data point")
        };
        let json = format!(
            "{{\n  \"bench\": \"parallel_sim\",\n  \"workload\": \
             \"cvr-sync n_per={n_per} d={d} rounds={rounds}\",\n  \"seeded\": true,\n  \
             \"host_cores\": {cores},\n  \"note\": \"{note}\",\n  \"runs\": [\n{}\n  ]\n}}\n",
            entries.join(",\n")
        );
        let out_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../results");
        let path = format!("{out_dir}/BENCH_parallel_sim.json");
        if let Err(e) = std::fs::create_dir_all(out_dir)
            .and_then(|()| std::fs::write(&path, &json))
        {
            println!("hot_paths/parallel_sim: could not write {path}: {e}");
        } else {
            println!("hot_paths/parallel_sim: wrote {path}");
        }
        print!("{json}");
    }

    // --- quantized wire payload sizes ---
    // Exact frame bytes per wire format at the Fig-2 text-scale d=5k,
    // verified against the codec (bytes() == encoded length), written to
    // results/BENCH_wire_bytes.json. Everything in the "exact" block is
    // a deterministic integer: tools/bench_diff.py hard-fails CI if any
    // of them drift from the committed baseline.
    if enabled("wire_bytes") {
        use centralvr::dist::codec::{self, WireFormat};
        use centralvr::dist::messages::GlobalView;
        let d = 5000usize;
        let nnz = 50usize; // 1% sparse delta
        let mut r = Pcg64::new(8);
        let dense: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
        let mut sparse = vec![0.0f32; d];
        for k in 0..nnz {
            // magnitudes in [0.5, 1.5]: no entry quantizes to zero, so
            // nnz (and the frame size) is layout-stable at every format
            sparse[k * (d / nnz)] = 0.5 + r.next_f32();
        }
        let frames: Vec<(&str, Upload)> = vec![
            ("delta_dense", Upload::Delta { dx: dense.clone(), dgbar: dense.clone() }),
            ("delta_sparse", Upload::Delta { dx: sparse.clone(), dgbar: sparse.clone() }),
            ("state_dense", Upload::State { x: dense.clone(), gbar: dense.clone() }),
            ("grad_partial_dense", Upload::GradPartial { gsum: dense.clone(), n: 1 }),
        ];
        let mut exact: Vec<(String, u64)> = Vec::new();
        for (name, up) in &frames {
            for wire in WireFormat::ALL {
                let mut grid = up.clone();
                match &mut grid {
                    Upload::Delta { dx, dgbar } => {
                        codec::quantize_in_place(dx, wire);
                        codec::quantize_in_place(dgbar, wire);
                    }
                    Upload::State { x, gbar } => {
                        codec::quantize_in_place(x, wire);
                        codec::quantize_in_place(gbar, wire);
                    }
                    Upload::GradPartial { gsum, .. } => codec::quantize_in_place(gsum, wire),
                    _ => {}
                }
                let bytes = grid.bytes(wire);
                let encoded = codec::encode_upload(&grid, wire).len() as u64;
                assert_eq!(bytes, encoded, "{name}/{wire}: bytes() != encoded length");
                exact.push((format!("{name}_{wire}"), bytes));
            }
        }
        let view = GlobalView { x: dense.clone(), gbar: dense.clone() };
        exact.push(("view_f32".into(), view.bytes()));
        exact.push(("ready".into(), Upload::Ready.bytes(WireFormat::F32)));
        fn lookup(ex: &[(String, u64)], k: &str) -> u64 {
            ex.iter().find(|(n, _)| n == k).unwrap().1
        }
        // one CVR-Sync round per worker: State up, View down
        for wire in WireFormat::ALL {
            let round = lookup(&exact, &format!("state_dense_{wire}"))
                + lookup(&exact, "view_f32");
            exact.push((format!("cvr_sync_round_per_worker_{wire}"), round));
        }
        let ratio = lookup(&exact, "delta_dense_f32") as f64
            / lookup(&exact, "delta_dense_int8") as f64;
        b.metric("wire_bytes_delta_f32_over_int8", ratio, "x");
        assert!(ratio >= 3.5, "int8 payload shrink regressed: {ratio:.2}x");
        let entries: Vec<String> = exact
            .iter()
            .map(|(k, v)| format!("    \"{k}\": {v}"))
            .collect();
        let json = format!(
            "{{\n  \"bench\": \"wire_bytes\",\n  \"workload\": \
             \"payload frames at d={d}, sparse nnz={nnz}\",\n  \"seeded\": true,\n  \
             \"exact\": {{\n{}\n  }},\n  \
             \"metrics\": {{\n    \"delta_dense_f32_over_int8\": {ratio:.3}\n  }}\n}}\n",
            entries.join(",\n")
        );
        let out_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../results");
        let path = format!("{out_dir}/BENCH_wire_bytes.json");
        if let Err(e) = std::fs::create_dir_all(out_dir)
            .and_then(|()| std::fs::write(&path, &json))
        {
            println!("hot_paths/wire_bytes: could not write {path}: {e}");
        } else {
            println!("hot_paths/wire_bytes: wrote {path}");
        }
        print!("{json}");
    }

    // --- hostile-network scenario sweep ---
    // CVR-Async and PS-SVRG over a latency-profile x staleness-bound
    // grid; each cell self-checks serial vs 3-thread bit-identity and the
    // convergence-vs-staleness curves land in
    // results/BENCH_scenario_sweep.json.
    if enabled("scenario_sweep") {
        use centralvr::harness::{scenario, Scale};
        let out_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../results");
        let t0 = std::time::Instant::now();
        let cells = scenario::sweep(Scale::Quick).expect("scenario sweep");
        b.metric("scenario_sweep_cells", cells.len() as f64, "runs");
        b.metric("scenario_sweep_wall_s", t0.elapsed().as_secs_f64(), "s");
        let parked: u64 = cells
            .iter()
            .map(|c| c.rep.scenario.map(|s| s.stale_parked).unwrap_or(0))
            .sum();
        b.metric("scenario_sweep_stale_parked_total", parked as f64, "uploads");
        let json = scenario::to_json(Scale::Quick, &cells);
        let path = format!("{out_dir}/BENCH_scenario_sweep.json");
        if let Err(e) = std::fs::create_dir_all(out_dir)
            .and_then(|()| std::fs::write(&path, &json))
        {
            println!("hot_paths/scenario_sweep: could not write {path}: {e}");
        } else {
            println!("hot_paths/scenario_sweep: wrote {path}");
        }
    }
}
