//! Bench: regenerates Fig 3 — SUSY-like / MILLIONSONG-like convergence and
//! strong scaling on the simulated cluster.

mod common;

use centralvr::harness::fig3;
use centralvr::harness::Scale;

fn main() {
    let b = common::Bench::group("fig3");
    for (panel, algo, rep) in fig3::convergence(Scale::Quick) {
        b.outcome(
            &format!("conv/{panel}/{}", algo.name()),
            format!(
                "t_to_1e-5={} best_rel={:.2e}",
                rep.trace
                    .time_to(1e-5)
                    .map(|t| format!("{t:.3}s"))
                    .unwrap_or_else(|| "—".into()),
                rep.trace.series.best_rel()
            ),
        );
    }
    for (panel, algo, p, t) in fig3::scaling(Scale::Quick) {
        b.outcome(
            &format!("scale/{panel}/{}/p{p}", algo.name()),
            t.map(|t| format!("{t:.3}s")).unwrap_or_else(|| "—".into()),
        );
    }
}
